"""Sharded serving: plan-aware placement + exchange + engine parity.

Host-side cases (placement policy, byte accounting, spec emission, plan
annotation round-trip, engine validation) run on the single-device view.
The multi-device cases — exchange bitwise-vs-local, sharded-vs-single-
host engine parity (uniform and mixed-width plans, empty bags, device
cache on) — run in a subprocess with 8 forced host devices, one bundle
per process to amortize the mesh startup (the test_dist.py idiom).
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.dist.accounting import (ring_all_to_all_bytes,
                                   serve_exchange_wire_bytes,
                                   serve_wave_wire_bytes)
from repro.dist.serve_placement import (ServePlacement, plan_placement,
                                        sub_table_items)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _quantized_dlrm(emb_dim=16):
    import dataclasses

    from repro.configs import dlrm_criteo
    from repro.serve.quantize import quantize_params

    cfg = dataclasses.replace(dlrm_criteo.config(reduced=True),
                              emb_dim=emb_dim)
    api = dlrm_criteo.api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, params, quantize_params(params, mode="int8")


# ------------------------------------------------------------ placement


def test_plan_placement_bounds_per_device_bytes():
    cfg, _, qparams = _quantized_dlrm()
    n = 8
    pl = plan_placement(qparams, n)
    assert pl.n_devices == n
    assert len(pl.entries) == len(sub_table_items(qparams))
    assert pl.sharded, "nothing sharded — threshold too high for the config"
    for e in pl.sharded:
        assert e.rows >= n and e.bytes_total > pl.threshold_bytes
        assert e.padded_rows % n == 0 and e.padded_rows >= e.rows
        assert pl.rows_per_device(e) * n == e.padded_rows
    for e in pl.replicated:
        assert e.padded_rows == e.rows
    # the acceptance bound the bench gates on, from the placement's own
    # accounting: every device holds the replicated set + 1/N of the rest
    assert pl.bytes_per_device() <= (pl.total_bytes() // n
                                     + pl.replicated_bytes() + pl.pad_bytes())


def test_plan_placement_single_device_replicates_everything():
    _, _, qparams = _quantized_dlrm()
    pl = plan_placement(qparams, 1)
    assert not pl.sharded
    assert pl.bytes_per_device() == pl.total_bytes()
    assert bool(pl.replicated_features(len(pl.entries)).all())


def test_placement_round_trips_through_plan_json():
    from repro.plan import plan_for_config

    cfg, _, qparams = _quantized_dlrm()
    plan = plan_for_config(cfg, 1 << 18, bytes_domain="serve_int8",
                           num_batches=4, batch_size=128)
    pl = plan_placement(qparams, 8, plan=plan)
    # threshold derives from the plan's byte claim, not the built params
    assert pl.threshold_bytes == max(1, plan.total_bytes // (4 * 8))
    plan.annotate_placement(pl)
    back = type(plan).from_json(plan.to_json()).serve_placement()
    assert back is not None and back.as_dict() == pl.as_dict()
    assert ServePlacement.from_dict(pl.as_dict()).as_dict() == pl.as_dict()


def test_replicated_features_masks_row_sharded_features():
    _, _, qparams = _quantized_dlrm()
    pl = plan_placement(qparams, 8)
    f = len(qparams["tables"])
    mask = pl.replicated_features(f)
    sharded_feats = {e.feature for e in pl.sharded}
    for i in range(f):
        assert mask[i] == (i not in sharded_feats)


def test_placement_specs_shard_rows_only():
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import placement_specs

    _, _, qparams = _quantized_dlrm()
    pl = plan_placement(qparams, 8)
    # pad sharded leaves the way place_params would before fitting specs
    import jax.numpy as jnp

    def pad(leaf, rows):
        return jnp.concatenate(
            [leaf, jnp.zeros((rows - leaf.shape[0],) + leaf.shape[1:],
                             leaf.dtype)])
    padded = jax.tree.map(lambda x: x, qparams)  # fresh containers
    for e in pl.sharded:
        sub = padded["tables"][e.feature][e.table_key]
        for k in sub:
            sub[k] = pad(sub[k], e.padded_rows)
    specs = placement_specs(padded, pl)
    sharded_paths = {(e.feature, e.table_key) for e in pl.sharded}
    for i, tp in enumerate(specs["tables"]):
        for key, sub in tp.items():
            for spec in jax.tree.leaves(sub, is_leaf=lambda s:
                                        isinstance(s, P)):
                if (i, key) in sharded_paths:
                    assert spec[0] == "data", (i, key, spec)
                else:
                    assert all(ax is None for ax in spec), (i, key, spec)


# ------------------------------------------------------------ accounting


def test_serve_exchange_wire_bytes_closed_form():
    n, lookups, width = 8, 96, 32
    q = serve_exchange_wire_bytes(lookups, width, n, quantized=True)
    ids = ring_all_to_all_bytes(4.0 * n * lookups, n)
    rows = (ring_all_to_all_bytes(1.0 * n * lookups * width, n)
            + ring_all_to_all_bytes(2.0 * n * lookups, n)
            + ring_all_to_all_bytes(1.0 * n * lookups, n))
    assert q["ids_bytes"] == ids
    assert q["total_bytes"] == ids + rows
    d = serve_exchange_wire_bytes(lookups, width, n, quantized=False)
    assert d["rows_bytes"] == ring_all_to_all_bytes(
        4.0 * n * lookups * width, n)
    # int8-on-the-wire beats f32 rows once width amortizes the meta
    assert q["rows_bytes"] < d["rows_bytes"]


def test_serve_wave_wire_bytes_sums_sharded_entries():
    _, _, qparams = _quantized_dlrm()
    pl = plan_placement(qparams, 8)
    acct = serve_wave_wire_bytes(pl, batch_per_device=32, bag_len=4)
    assert acct["lookups_per_device"] == 128
    assert len(acct["per_entry"]) == len(pl.sharded)
    assert acct["total_bytes"] == sum(e["total_bytes"]
                                      for e in acct["per_entry"])
    none_sharded = plan_placement(qparams, 1)
    assert serve_wave_wire_bytes(none_sharded, 32, 4)["total_bytes"] == 0


# ------------------------------------------------------------ validation


def test_engine_sharded_mode_validation():
    import dataclasses

    from repro.serve.cache import HotRowCache
    from repro.serve.recsys import RecsysEngine

    cfg, _, qparams = _quantized_dlrm()
    with pytest.raises(ValueError, match="multiple of"):
        RecsysEngine(cfg, qparams, max_batch=12, mesh_devices=8)
    with pytest.raises(NotImplementedError, match="DeviceHotRowCache"):
        RecsysEngine(cfg, qparams, max_batch=16, mesh_devices=8,
                     cache=HotRowCache())
    kcfg = dataclasses.replace(cfg, use_kernel=True)
    with pytest.raises(NotImplementedError, match="kernel"):
        RecsysEngine(kcfg, qparams, max_batch=16, mesh_devices=8)


# ------------------------------------------------------------ 8-device


_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.configs import dlrm_criteo
    from repro.core.compositional import table_rows
    from repro.dist.serve_placement import exchange_rows, plan_placement
    from repro.plan import plan_for_config
    from repro.serve.cache import DeviceHotRowCache
    from repro.serve.quantize import quantize_params
    from repro.serve.recsys import RecsysEngine, _FEATURE_SHIFT

    out = {}
    n = 8
    mesh = jax.make_mesh((n,), ("data",))

    # --- exchange_rows vs local table_rows: bitwise, f32 and quantized
    rng = np.random.default_rng(0)
    rows, width = 64, 12
    table = jnp.asarray(rng.normal(size=(rows, width)).astype(np.float32))
    qt = {"q": jnp.asarray(rng.integers(-128, 128, (rows, width)), jnp.int8),
          "scale": jnp.asarray(rng.random((rows, 1)).astype(np.float32) / 10
                               ).astype(jnp.bfloat16),
          "zp": jnp.asarray(rng.integers(-8, 8, (rows, 1)), jnp.int8)}
    ids = jnp.asarray(rng.integers(0, rows, (16, 5)), jnp.int32)

    def run_ex(leaf):
        fn = shard_map(
            lambda l, i: exchange_rows(l, i, n, rows // n, axis="data"),
            mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P("data"))
        return jax.jit(fn)(leaf, ids)

    got = np.asarray(run_ex(table))
    want = np.asarray(table_rows(table, ids))
    out["exchange_f32_bitwise"] = bool(np.array_equal(got, want))
    got_q = np.asarray(run_ex(qt))
    want_q = np.asarray(table_rows(qt, ids))
    out["exchange_quant_bitwise"] = bool(np.array_equal(got_q, want_q))

    # --- engine parity: sharded vs single-host, waves mode
    def stream(cfg, count, max_bag=8):
        r = np.random.default_rng(1)
        reqs = []
        f = len(cfg.table_sizes)
        for k in range(count):
            L = max_bag if k % 32 == 0 else 1 + (k * 7) % max_bag
            dense = r.normal(size=(13,)).astype(np.float32)
            bags = [list((r.integers(0, s, size=L)).astype(int))
                    for s in cfg.table_sizes]
            if k % 4 == 1:
                bags[k % f] = []          # empty bag -> zero-vector pool
            reqs.append((dense, bags))
        return reqs

    def scores(engine, reqs):
        uids = [engine.submit(d, b) for d, b in reqs]
        done = engine.run_until_drained()
        return np.asarray([done[u].score for u in uids], np.float32)

    def parity(cfg, qparams, reqs, cache=None):
        e1 = RecsysEngine(cfg, qparams, max_batch=16, batching="waves")
        e8 = RecsysEngine(cfg, qparams, max_batch=128, batching="waves",
                          mesh_devices=n, cache=cache)
        return scores(e1, reqs), scores(e8, reqs), e8

    cfg, qp = None, None
    cfg = dataclasses.replace(dlrm_criteo.config(reduced=True), emb_dim=16)
    api = dlrm_criteo.api(cfg)
    qp = quantize_params(api.init(jax.random.PRNGKey(0)), mode="int8")
    reqs = stream(cfg, 128)
    s1, s8, _ = parity(cfg, qp, reqs)
    out["parity_uniform_bitwise"] = bool(np.array_equal(s1, s8))

    # --- mixed-width plan (distinct per-feature dims + projections)
    plan = plan_for_config(cfg, 1 << 17, bytes_domain="serve_int8",
                           num_batches=4, batch_size=128, dims=(4, 8, 16))
    mcfg = dlrm_criteo.config(reduced=True, plan=plan)
    mapi = dlrm_criteo.api(mcfg)
    mqp = quantize_params(mapi.init(jax.random.PRNGKey(1)), mode="int8")
    out["mixed_widths"] = len(set(plan.table_dims)) > 1
    mreqs = stream(mcfg, 128)
    m1, m8, _ = parity(mcfg, mqp, mreqs)
    out["parity_mixed_bitwise"] = bool(np.array_equal(m1, m8))

    # --- device cache on: parity, hits, and locality of admitted keys
    cache = DeviceHotRowCache(capacity_rows=1 << 14)
    c1, c8, e8c = parity(cfg, qp, reqs, cache=cache)
    out["parity_cache_bitwise"] = bool(np.array_equal(c1, c8))
    scores(e8c, reqs)                      # second pass hits the cache
    out["cache_hit_rate"] = float(e8c.metrics()["cache"]["hit_rate"])
    keys, _ = cache.slot_items()
    feats = set((np.asarray(keys) >> _FEATURE_SHIFT).tolist())
    repl = {i for i in range(len(cfg.table_sizes))
            if e8c.placement.replicated_features(len(cfg.table_sizes))[i]}
    out["cache_keys_replicated_only"] = feats <= repl and bool(feats)

    print(json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_serving_8dev_bundle():
    res = subprocess.run([sys.executable, "-c", _CHILD],
                         capture_output=True, text=True,
                         env=dict(os.environ, PYTHONPATH=f"{REPO}/src"),
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["exchange_f32_bitwise"]
    assert out["exchange_quant_bitwise"]
    assert out["parity_uniform_bitwise"]
    assert out["mixed_widths"]
    assert out["parity_mixed_bitwise"]
    assert out["parity_cache_bitwise"]
    assert out["cache_hit_rate"] > 0
    assert out["cache_keys_replicated_only"]
