"""Checkpointing: roundtrip, integrity fallback, async, pruning, resharding."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "tables": [jax.random.normal(k, (10, 2)),
                                  jax.random.normal(k, (5, 2))]},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 7, tree)
    restored, manifest = ckpt.restore(str(tmp_path), 7, tree)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_prune(tmp_path):
    tree = _tree()
    for s in (10, 20, 30, 40):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.available_steps(str(tmp_path)) == [30, 40]
    assert ckpt.latest_step(str(tmp_path)) == 40


def test_corrupt_falls_back_to_previous(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 10, tree)
    ckpt.save(str(tmp_path), 20, tree)
    # corrupt the newest checkpoint's first leaf file
    d = os.path.join(str(tmp_path), "step_00000020")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    victim = os.path.join(d, manifest["leaves"][0]["file"])
    with open(victim, "wb") as f:
        f.write(b"garbage")
    step, restored, _ = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 10 and restored is not None
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 20, tree)


def test_interrupted_write_is_invisible(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 10, tree)
    # simulate a crash mid-write: a .tmp dir left behind
    os.makedirs(os.path.join(str(tmp_path), "step_00000099.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_async_checkpointer(tmp_path):
    tree = _tree()
    ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    ac.save(5, tree)
    ac.save(6, tree)  # waits for 5 internally
    ac.wait()
    assert ckpt.available_steps(str(tmp_path)) == [5, 6]


def test_restore_respects_target_dtype(tmp_path):
    tree = {"w": jnp.ones((4, 4), jnp.float32)}
    ckpt.save(str(tmp_path), 1, tree)
    like = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
    restored, _ = ckpt.restore(str(tmp_path), 1, like)
    assert restored["w"].dtype == jnp.bfloat16


def test_leaf_count_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros(3), "b": jnp.zeros(2)})
