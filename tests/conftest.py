"""Test-session bootstrap: fall back to the in-repo hypothesis stub when the
real package is unavailable (hermetic sandboxes; CI installs the real one)."""

try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro._compat import hypothesis_stub
    hypothesis_stub.install()
