"""repro.obs: registry semantics, tracer/Chrome-trace export, engine
stage timelines, collision telemetry, and the read-only contract
(obs-on must not change a single score)."""

import json

import jax
import numpy as np
import pytest

from repro.core import EmbeddingSpec
from repro.data.criteo import CriteoSpec, batch_at
from repro.models.dlrm import DLRMConfig, dlrm_init, dlrm_loss_fn, tables_for
from repro.obs import CollisionTelemetry, MetricsRegistry, Obs, Tracer
from repro.obs.collision import predicted_collision_mass
from repro.optim.optimizers import adagrad
from repro.plan.freq import FeatureStats
from repro.serve.cache import HotRowCache
from repro.serve.quantize import quantize_params
from repro.serve.recsys import STAGE_PARTITION, STAGES, RecsysEngine
from repro.train.loop import TrainConfig, Trainer, init_state, make_train_step

SIZES = (100, 500, 33)


def _cfg(**kw):
    base = dict(table_sizes=SIZES, emb_dim=16, bottom_mlp=(32, 16),
                top_mlp=(32,),
                embedding=EmbeddingSpec(kind="qr", num_collisions=4,
                                        threshold=40))
    base.update(kw)
    return DLRMConfig(**base)


def _requests(n, seed=0, sizes=SIZES, max_bag=3):
    rng = np.random.default_rng(seed)
    return [(rng.normal(size=13),
             [list(rng.integers(0, s, size=rng.integers(1, max_bag + 1)))
              for s in sizes])
            for _ in range(n)]


# ------------------------------------------------------------------ registry


def test_registry_get_or_create_and_type_clash():
    reg = MetricsRegistry()
    c = reg.counter("requests", "help text")
    assert reg.counter("requests") is c
    with pytest.raises(TypeError):
        reg.gauge("requests")
    with pytest.raises(TypeError):
        reg.histogram("requests")


def test_counter_and_gauge_label_semantics():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    c.inc(2, stage="a")
    c.inc(3, stage="a")
    c.inc(5, stage="b")
    assert c.value(stage="a") == 5
    assert c.value(stage="b") == 5
    # label order must not matter: one series per label *set*
    h1 = c.labels(x="1", y="2")
    h2 = c.labels(y="2", x="1")
    assert h1 is h2
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(7, q="main")
    g.set(3, q="main")
    assert g.value(q="main") == 3


def test_histogram_percentiles_match_numpy():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    rng = np.random.default_rng(0)
    samples = rng.lognormal(size=257)
    for s in samples:
        h.observe(float(s))
    for q in (0, 10, 50, 90, 99, 100):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(samples, q)), rel=1e-12)
    summ = h.labels().summary()
    assert summ["count"] == len(samples)
    assert summ["sum"] == pytest.approx(float(samples.sum()))
    assert summ["p99"] == pytest.approx(float(np.percentile(samples, 99)))
    with pytest.raises(ValueError):
        reg.histogram("empty").percentile(50)
    with pytest.raises(ValueError):
        h.percentile(101)


def test_histogram_bounded_samples_drop_oldest():
    reg = MetricsRegistry()
    h = reg.histogram("b", max_samples=4)
    for v in range(10):
        h.observe(float(v))
    s = h.labels()
    assert s.samples == [6.0, 7.0, 8.0, 9.0]
    assert s.count == 10          # count/sum keep the full traffic
    assert s.sum == float(sum(range(10)))


def test_registry_merge_semantics():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("c").inc(1, k="x")
    b.counter("c").inc(2, k="x")
    b.counter("c").inc(7, k="y")
    a.gauge("g").set(1)
    b.gauge("g").set(9)
    a.histogram("h").observe(1.0)
    b.histogram("h").observe(3.0)
    a.merge(b)
    assert a.counter("c").value(k="x") == 3      # counters sum
    assert a.counter("c").value(k="y") == 7
    assert a.gauge("g").value() == 9             # gauge: other wins
    s = a.histogram("h").labels()
    assert sorted(s.samples) == [1.0, 3.0]       # histograms union
    assert s.count == 2 and s.sum == 4.0


def test_registry_reset_keeps_bound_handles_live():
    reg = MetricsRegistry()
    c = reg.counter("serve_requests").labels()
    h = reg.histogram("serve_lat").labels()
    other = reg.counter("train_steps").labels()
    c.inc(5)
    h.observe(1.0)
    other.inc(2)
    reg.reset(prefix="serve_")
    assert c.value == 0 and h.count == 0 and h.samples == []
    assert other.value == 2                      # prefix respected
    c.inc(1)                                     # old handle still works
    assert reg.counter("serve_requests").value() == 1


def test_registry_jsonl_round_trip():
    reg = MetricsRegistry()
    reg.counter("c").inc(3, k="x")
    reg.histogram("h").observe(2.0)
    recs = [json.loads(line) for line in reg.to_jsonl().splitlines()]
    by_name = {r["name"]: r for r in recs}
    assert by_name["c"]["type"] == "counter"
    assert by_name["c"]["value"] == 3
    assert by_name["c"]["labels"] == {"k": "x"}
    assert by_name["h"]["type"] == "histogram"
    assert by_name["h"]["count"] == 1


# ------------------------------------------------------------------- tracer


def test_tracer_nesting_and_chrome_trace_round_trip():
    tr = Tracer()
    with tr.span("outer", kind="t"):
        with tr.span("inner"):
            pass
    tr.instant("mark")
    payload = json.loads(tr.to_json())
    evs = payload["traceEvents"]
    by_name = {e["name"]: e for e in evs}
    # inner closes (and records) before outer
    assert [e["name"] for e in evs] == ["inner", "outer", "mark"]
    assert by_name["outer"]["args"]["depth"] == 0
    assert by_name["inner"]["args"]["depth"] == 1
    for e in evs:
        assert e["ph"] in ("X", "i") and e["ts"] >= 0
    # inner nests inside outer on the chrome timeline
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-3
    assert len(tr) == 3
    assert len(tr.drain()) == 3 and len(tr) == 0


def test_tracer_fence_passthrough_and_bound():
    x = jax.numpy.ones(3)
    assert Tracer().fence(x) is x                 # disabled: no-op
    assert Tracer(fence=True).fence(x) is x       # enabled: blocks, returns
    tr = Tracer(max_events=2)
    for k in range(5):
        tr.complete(f"e{k}", 0.0, 0.0)
    assert [e["name"] for e in tr.drain()] == ["e3", "e4"]


# ------------------------------------------------------- collision telemetry


def test_collision_measured_equals_predicted_on_same_distribution():
    """Same estimator, same distribution -> the measured and predicted
    collision masses must agree exactly (the bench's table compares the
    two under *different* distributions; here we pin the estimators)."""
    # hash tables (lossy by construction): ids 0 and m share a bucket,
    # so the collision mass is deterministically nonzero
    cfg = _cfg(embedding=EmbeddingSpec(kind="hash", num_collisions=4,
                                       threshold=40))
    mods = tables_for(cfg)
    m = mods[1].m
    assert 1 < m < SIZES[1]
    ct = CollisionTelemetry(SIZES, compact_every=2)
    ids = np.array([0, m, 0, m, 1])
    idx = np.zeros((5, 3, 1), np.int64)
    idx[:, 1, 0] = ids
    mask = np.zeros((5, 3, 1), np.int32)
    mask[:, 1, 0] = 1
    ct.record(idx, mask)
    assert ct.observed_lookups(1) == 5
    assert ct.observed_support(1) == 3
    assert ct.observed_lookups(0) == 0            # masked features drop out
    measured = ct.measured_collision_mass(mods[1], 1)
    assert measured > 0 and np.isfinite(measured)
    st = ct.observed_stats(1)
    assert st.ids.tolist() == [0, 1, m]
    assert st.probs.tolist() == [0.4, 0.2, 0.4]
    predicted = predicted_collision_mass(mods[1], st)
    assert measured == pytest.approx(predicted)
    # drifted stats -> the comparison moves (the signal the table exists
    # for): ids 0 and 1 land in distinct hash buckets, zero collision mass
    drifted = FeatureStats(size=SIZES[1], ids=np.array([0, 1]),
                           probs=np.array([0.5, 0.5]))
    assert predicted_collision_mass(mods[1], drifted) == 0.0
    assert measured != pytest.approx(0.0)


def test_collision_live_rows_trim_and_report():
    ct = CollisionTelemetry(SIZES, compact_every=64)
    idx = np.ones((4, 3, 2), np.int64)
    ct.record(idx, np.ones((4, 3, 2), np.int32), live_rows=2)
    assert ct.observed_lookups(0) == 4            # 2 live rows x bag of 2
    assert ct.requests == 2 and ct.waves == 1
    rows = ct.report(tables_for(_cfg()))
    assert [r["feature"] for r in rows] == [0, 1, 2]
    assert all(r["observed_support"] == 1 for r in rows)
    assert all(np.isfinite(r["measured_collision_mass"]) for r in rows)


# -------------------------------------------------------------- engine obs


def test_engine_stage_partition_sums_to_latency():
    cfg = _cfg()
    qp = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    for batching in ("continuous", "waves"):
        obs = Obs(trace=True, collisions=True)
        eng = RecsysEngine(cfg, qp, max_batch=4,
                           cache=HotRowCache(capacity_rows=512),
                           batching=batching, obs=obs)
        reqs = _requests(13, seed=3)
        uids = [eng.submit(d, b) for d, b in reqs]
        done = eng.run_until_drained()
        assert len(done) == len(uids)
        ss = eng.stage_summary()
        assert set(STAGES) <= set(ss)
        # the five partition stages tile [t0, t1]: ratio 1 by construction
        assert ss["partition"]["ratio"] == pytest.approx(1.0, abs=1e-9)
        assert ss["partition"]["latency_sum_s"] > 0
        waves = ss["probe"]["count"]
        assert waves > 0
        assert all(ss[s]["count"] == waves for s in STAGE_PARTITION)
        assert obs.registry.counter("serve_requests_total").value() \
            == len(reqs)
        # one wave bar + one bar per partition stage per wave
        names = [e["name"] for e in obs.tracer.events]
        assert names.count("wave") == waves
        for s in STAGE_PARTITION:
            assert names.count(s) == waves
        assert obs.collisions is not None and obs.collisions.waves == waves


def test_engine_obs_zero_requests_and_all_empty_bags():
    cfg = _cfg()
    qp = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    obs = Obs(trace=True, collisions=True)
    eng = RecsysEngine(cfg, qp, max_batch=4, obs=obs)
    # zero traffic: summaries exist, ratio degrades to 1.0, nothing raises
    ss = eng.stage_summary()
    assert ss["partition"]["ratio"] == 1.0
    assert ss["probe"]["count"] == 0
    assert eng.run_until_drained() == {}
    # all-empty-bag wave: every feature pools to the zero vector but the
    # wave still flows through every stage of the timeline
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.normal(size=13), [[], [], []]) for _ in range(3)]
    done = eng.run_until_drained()
    assert all(np.isfinite(done[u].score) for u in uids)
    ss = eng.stage_summary()
    assert ss["probe"]["count"] > 0
    assert ss["partition"]["ratio"] == pytest.approx(1.0, abs=1e-9)
    assert obs.collisions.observed_lookups(0) == 0   # no live ids served


def test_engine_obs_is_read_only_bitwise():
    cfg = _cfg()
    qp = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    reqs = _requests(17, seed=5) * 2
    eng_off = RecsysEngine(cfg, qp, max_batch=4,
                           cache=HotRowCache(capacity_rows=512))
    eng_on = RecsysEngine(cfg, qp, max_batch=4,
                          cache=HotRowCache(capacity_rows=512),
                          obs=Obs(trace=True, collisions=True))
    uids = [(eng_off.submit(d, b), eng_on.submit(d, b)) for d, b in reqs]
    done_off, done_on = eng_off.run_until_drained(), eng_on.run_until_drained()
    for a, b in uids:
        assert done_on[b].score == done_off[a].score


def test_reset_metrics_resets_cache_counters_keeps_residency():
    """The PR-8 bugfix pin: reset_metrics() must drop cache *traffic*
    counters with the timing stats (so steady-state hit rates exclude the
    cold fill) while the resident rows — and their byte accounting —
    survive.  A replayed resident stream then hits at exactly 1.0."""
    cfg = _cfg()
    qp = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    obs = Obs()
    eng = RecsysEngine(cfg, qp, max_batch=4,
                       cache=HotRowCache(capacity_rows=2048), obs=obs)
    reqs = _requests(16, seed=7)
    for d, b in reqs:
        eng.submit(d, b)
    eng.run_until_drained()
    st = eng.cache.stats
    assert st.lookups > 0 and st.misses > 0 and st.bytes_cached > 0
    resident = st.bytes_cached

    eng.reset_metrics()
    st = eng.cache.stats
    assert (st.hits, st.misses, st.lookups) == (0, 0, 0)
    assert st.bytes_cached == resident            # rows stayed resident
    assert eng.wave_latencies_s == []
    assert obs.registry.counter("serve_requests_total").value() == 0

    for d, b in reqs:                              # replay: fully resident
        eng.submit(d, b)
    eng.run_until_drained()
    m = eng.metrics()
    assert m["cache"]["hit_rate"] == 1.0
    assert m["cache"]["misses"] == 0


# -------------------------------------------------------------- trainer obs


def test_trainer_obs_counters_and_wire_handles():
    spec = CriteoSpec(table_sizes=SIZES)
    cfg = _cfg()

    def loss_fn(p, b):
        return dlrm_loss_fn(p, b, cfg)

    opt = adagrad(1e-2)
    state = init_state(dlrm_init(jax.random.PRNGKey(0), cfg), opt)
    obs = Obs(trace=True)
    step_wire = {"per_leaf": [{"path": "tables/0", "mode": "int8",
                               "nelems": 100, "wire_bytes": 123.0}],
                 "total_bytes": 200.0}
    tr = Trainer(make_train_step(loss_fn, opt),
                 TrainConfig(num_steps=6, log_every=2),
                 batch_at=lambda s: batch_at(0, s, 16, spec),
                 obs=obs, step_wire=step_wire)
    tr.run(state)
    reg = obs.registry
    assert reg.counter("train_steps_total").value() == 6
    h = reg.histogram("train_step_seconds").labels()
    assert h.count == 6 and h.sum > 0
    wire = reg.counter("train_wire_bytes_total")
    assert wire.value(leaf="tables/0", mode="int8") == 6 * 123.0
    assert wire.value(leaf="_other", mode="aggregate") == 6 * 77.0
    steps = [e for e in obs.tracer.events if e["name"] == "train_step"]
    assert [e["args"]["step"] for e in steps] == list(range(6))
