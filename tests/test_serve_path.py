"""The serving hot path, proven: differential grid for the fused
gather→dequant→pool→project kernel against the jnp oracle, hypothesis
property tests for the device-resident hot-row cache, and a pinned
512-request golden trace showing the continuous-batching engine is
bit-identical to the oracle pipeline with the cache on and off."""

import hashlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import EmbeddingSpec
from repro.kernels import ops, ref
from repro.kernels.serve_path import fused_serve_pool
from repro.models.dlrm import DLRMConfig, dlrm_forward, dlrm_init
from repro.plan import (build_plan, dim_ladder, full_table_bytes,
                        power_law_stats)
from repro.serve.cache import CachePinned, DeviceHotRowCache, HotRowCache
from repro.serve.quantize import quantize_params, quantize_table
from repro.serve.recsys import RecsysEngine

SIZES = (100, 500, 33, 2000)
DIM = 16

# ------------------------------------------------------------------ helpers


def _meta(q):
    return jnp.concatenate([q["scale"].astype(jnp.float32),
                            q["zp"].astype(jnp.float32)], axis=1)


def _tables(key, rows_a, rows_b, d, mode):
    """(w_a, w_b, meta_a, meta_b) in the requested serving mode."""
    ka, kb = jax.random.split(key)
    wa = jax.random.normal(ka, (rows_a, d), jnp.float32)
    wb = jax.random.normal(kb, (rows_b, d), jnp.float32)
    if mode == "int8":
        qa, qb = quantize_table(wa), quantize_table(wb)
        return qa["q"], qb["q"], _meta(qa), _meta(qb)
    dt = jnp.bfloat16 if mode == "bf16" else jnp.float32
    return wa.astype(dt), wb.astype(dt), None, None


def _bags(key, b, l, hi):
    """(idx, mask) with one fully-empty bag row (row b-1) whenever b > 1."""
    ki, km = jax.random.split(key)
    idx = jax.random.randint(ki, (b, l), 0, hi)
    mask = (jax.random.uniform(km, (b, l)) > 0.3).astype(jnp.float32)
    if b > 1 and l > 0:
        mask = mask.at[b - 1].set(0.0)     # empty bag pools to exact zero
    return idx, mask


def _tol(mode):
    # one f32 accumulation-order difference is allowed between the kernel's
    # sequential bag sum and the oracle's axis reduction; bf16 outputs round
    # once to bf16 so the bound widens to its eps
    return {"f32": 2e-5, "int8": 2e-5, "bf16": 2e-2}[mode]


# ------------------------------------------------- tentpole differential grid


@pytest.mark.parametrize("mode", ["f32", "bf16", "int8"])
@pytest.mark.parametrize("l", [0, 1, 7, 16])
def test_fused_kernel_matches_oracle_grid(mode, l):
    """{f32, bf16, int8} × L ∈ {0, 1, 7, 16} × D ∈ {16, 64, 128} ×
    {uniform, mixed-width} — kernel (interpret) vs ``kernels.ref`` oracle,
    QR pair and pre-folded single table, empty bags included (L=0 is the
    all-empty wave: the wrapper pads to one masked slot)."""
    b, m = 3, 10
    for cell, d_out in enumerate((16, 64, 128)):
        for mixed in (False, True):
            d = d_out // 2 if mixed else d_out
            key = jax.random.PRNGKey(17 * cell + mixed)
            wa, wb, ma, mb = _tables(key, m, 5, d, mode)
            proj = jax.random.normal(jax.random.fold_in(key, 3),
                                     (d, d_out)) if mixed else None
            idx, mask = _bags(jax.random.fold_in(key, 4), b, l, m * 5)
            pairs = [dict(idx_a=idx % m, idx_b=idx // m, w_b=wb,
                          meta_b=mb)]
            if d_out == 16:   # single-table (full/hash) variant of the cell
                pairs.append(dict(idx_a=idx % m))
            for kw in pairs:
                got = fused_serve_pool(mask=mask, w_a=wa, meta_a=ma,
                                       proj=proj, op="mult", **kw)
                want = ref.fused_serve_pool_ref(mask=mask, w_a=wa,
                                                meta_a=ma, proj=proj,
                                                op="mult", **kw)
                assert got.shape == want.shape and got.dtype == want.dtype
                np.testing.assert_allclose(
                    np.asarray(got, np.float32),
                    np.asarray(want, np.float32),
                    rtol=_tol(mode), atol=_tol(mode),
                    err_msg=f"{mode} L={l} D={d_out} mixed={mixed}")
                # the empty bag row pools (and projects) to exact zero
                if b > 1:
                    np.testing.assert_array_equal(
                        np.asarray(got)[b - 1], 0.0)


def test_fused_kernel_add_op_and_validation():
    wa, wb, ma, mb = _tables(jax.random.PRNGKey(0), 8, 4, 16, "int8")
    idx, mask = _bags(jax.random.PRNGKey(1), 2, 5, 32)
    got = fused_serve_pool(idx % 8, mask, wa, idx_b=idx // 8, w_b=wb,
                           meta_a=ma, meta_b=mb, op="add")
    want = ref.fused_serve_pool_ref(idx % 8, mask, wa, idx_b=idx // 8,
                                    w_b=wb, meta_a=ma, meta_b=mb, op="add")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="pairs"):
        fused_serve_pool(idx % 8, mask, wa, idx_b=idx // 8, w_b=None)
    with pytest.raises(ValueError, match="pairs"):
        fused_serve_pool(idx % 8, mask, wa, idx_b=idx // 8, w_b=wb,
                         meta_a=ma, meta_b=None)


def test_serve_bag_pool_routing():
    """ops.serve_bag_pool: kernel path == oracle path == the unfusable
    fallbacks (concat, mixed dense+quant pair) on the same contract."""
    key = jax.random.PRNGKey(2)
    wa = jax.random.normal(key, (12, 8))
    wb = jax.random.normal(jax.random.fold_in(key, 1), (4, 8))
    qa, qb = quantize_table(wa), quantize_table(wb)
    proj = jax.random.normal(jax.random.fold_in(key, 2), (8, 16))
    idx = jax.random.randint(jax.random.fold_in(key, 3), (3, 6), 0, 48)
    mask = (jax.random.uniform(jax.random.fold_in(key, 4), (3, 6)) > 0.4
            ).astype(jnp.float32)
    for args in ((idx, mask, qa, qb), (idx, mask, wa, wb),
                 (idx % 12, mask, qa, None)):
        got = ops.serve_bag_pool(*args, proj=proj)
        want = ops.serve_bag_pool(*args, proj=proj, use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    # concat pair: jnp fallback, widths concatenate before the projection
    pc = jax.random.normal(jax.random.fold_in(key, 5), (16, 16))
    out = ops.serve_bag_pool(idx, mask, wa, wb, op="concat", proj=pc)
    rows = jnp.concatenate([jnp.take(wa, idx % 12, axis=0),
                            jnp.take(wb, idx // 12, axis=0)], axis=-1)
    pooled = (rows * mask[..., None]).sum(axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(pooled @ pc),
                               rtol=1e-5, atol=1e-5)
    # mixed dense+quant pair is not fusable; still matches the contract
    got = ops.serve_bag_pool(idx, mask, qa, wb)
    a = (jnp.take(qa["q"], idx % 12, axis=0).astype(jnp.float32)
         - qa["zp"][idx % 12]) * qa["scale"][idx % 12]
    b = jnp.take(wb, idx // 12, axis=0)
    want = ((a * b) * mask[..., None]).sum(axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------- device cache property harness


def _zipf_stream(seed, n, universe=40):
    rng = np.random.default_rng(seed)
    return [int(k) % universe for k in rng.zipf(1.3, size=n)]


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["lru", "lfu"]), st.integers(1, 12),
       st.integers(0, 10_000))
def test_device_cache_capacity_and_conservation(policy, cap, seed):
    """Row capacity never exceeded; insertions − evictions − invalidations
    always equals the resident count; every resident row reads back as the
    exact value admitted."""
    c = DeviceHotRowCache(capacity_rows=cap, policy=policy)
    for k in _zipf_stream(seed, 150):
        if c.get(k) is None:
            c.put(k, np.full(8, float(k) + 0.5, np.float32))
        assert len(c) <= cap
    s = c.stats
    assert s.insertions - s.evictions - s.invalidations == len(c)
    assert s.hits + s.misses == 150
    for k in list(c._rows):
        np.testing.assert_array_equal(
            c.get(k), np.full(8, float(k) + 0.5, np.float32))


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(["lru", "lfu"]), st.integers(64, 600),
       st.integers(0, 10_000))
def test_device_cache_byte_budget_mixed_widths(policy, cap_bytes, seed):
    """Byte budget never exceeded with mixed-width rows (the mixed-dim
    serving shape); oversized rows reject instead of flushing."""
    c = DeviceHotRowCache(capacity_rows=None, capacity_bytes=cap_bytes,
                          policy=policy)
    for k in _zipf_stream(seed, 120):
        width = 4 * (1 + k % 4)            # 4/8/12/16 f32 → 16..64 bytes
        if c.get(k) is None:
            c.put(k, np.full(width, float(k), np.float32))
        assert c.stats.bytes_cached <= cap_bytes
    assert c.stats.bytes_cached == sum(r.nbytes for r in c._rows.values())


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(["lru", "lfu"]), st.integers(2, 10),
       st.integers(0, 10_000))
def test_device_replay_bit_exact_and_matches_host(policy, cap, seed):
    """replay() is reproducible bit-exactly on a fresh device cache, and
    the device cache's event log + stats are identical to the host
    cache's for the same stream — storage residency must not leak into
    policy behaviour."""
    stream = _zipf_stream(seed, 100)
    logs, stats = [], []
    for cls in (HotRowCache, DeviceHotRowCache, DeviceHotRowCache):
        c = cls(capacity_rows=cap, policy=policy)
        logs.append(c.replay(stream, row_bytes=32))
        stats.append(c.stats.as_dict())
    assert logs[0] == logs[1] == logs[2]
    assert stats[0] == stats[1] == stats[2]


def test_device_cache_pinning_blocks_eviction():
    """put_many never evicts a pinned key: admission is rejected instead
    (the engine's same-wave slot-integrity guarantee)."""
    c = DeviceHotRowCache(capacity_rows=2)
    c.put_many([1, 2], np.ones((2, 4), np.float32))
    admitted = c.put_many([3], np.zeros((1, 4), np.float32), pinned=[1, 2])
    assert admitted == [] and c.stats.rejections == 1
    assert sorted(c._rows) == [1, 2]
    with pytest.raises(CachePinned):
        c._pinned = {1, 2}
        try:
            c._victim()
        finally:
            c._pinned = set()
    # unpinned, the same admission lands and evicts per policy
    assert c.put_many([3], np.zeros((1, 4), np.float32)) == [3]
    assert c.stats.evictions == 1


def test_device_cache_scatter_dedupes_reused_slot():
    """A slot freed by an eviction and reused in the same put_many wave
    must land the *newer* row (last-write-wins in the batched scatter)."""
    c = DeviceHotRowCache(capacity_rows=1)
    rows = np.stack([np.full(4, 1.0, np.float32),
                     np.full(4, 2.0, np.float32)])
    c.put_many([10, 11], rows)          # 10 admitted then evicted for 11
    assert list(c._rows) == [11]
    np.testing.assert_array_equal(c.get(11), rows[1])


def test_device_cache_lookup_many_counts_occurrences():
    c = DeviceHotRowCache(capacity_rows=8)
    c.put(5, np.ones(4, np.float32))
    slots, miss = c.lookup_many([5, 6], counts=np.array([3, 2]))
    assert slots[0] >= 0 and slots[1] == -1
    assert (miss == [False, True]).all()
    assert c.stats.hits == 3 and c.stats.misses == 2


# ----------------------------------------------- golden 512-request trace

TRACE_N = 512
# Pinned behavioural goldens for the recorded trace (floats are asserted
# by bit-identity *between* pipelines, never against literals):
GOLDEN_WAVES = 22
GOLDEN_BUCKETS = [(2, 1), (8, 2), (16, 1), (16, 2), (16, 4), (16, 8),
                  (32, 4), (32, 8)]
GOLDEN_CACHE = {"hits": 4072, "misses": 882, "evictions": 0,
                "insertions": 670, "rejections": 0, "invalidations": 0,
                "bytes_cached": 15184, "lookups": 4954,
                "hit_rate": 0.8219620508679855}
GOLDEN_EVENTS_SHA1 = "9b94b32e3db8749960d166043838d7d689f67568"


def _mixed_plan(frac=0.25):
    stats = [power_law_stats(n, alpha=1.2) for n in SIZES]
    return build_plan(stats, DIM, int(full_table_bytes(SIZES, DIM) * frac),
                      dims=dim_ladder(DIM), arch="serve-path-golden")


def _trace(n=TRACE_N, seed=11):
    rng = np.random.default_rng(seed)
    reqs = []
    for r in range(n):
        if r % 16 == 15:
            bags = [[] for _ in SIZES]             # all-empty request
        else:
            bags = [list((rng.zipf(1.3, size=int(rng.integers(0, 6)))
                          - 1) % s) for s in SIZES]
        reqs.append((rng.normal(size=13), bags))
    return reqs


class _RecordingEngine(RecsysEngine):
    """RecsysEngine that records every padded wave (the oracle replays
    the exact shapes the engine served)."""

    def _pad_wave(self, wave):
        out = super()._pad_wave(wave)
        self.trace = getattr(self, "trace", [])
        self.trace.append((out, [r.uid for r in wave]))
        return out


def test_golden_trace_engine_bit_identical_to_oracle():
    """The tentpole acceptance: over a recorded 512-request mixed-plan
    trace (quantized tables, empty bags, Zipf ids), the
    continuous-batching engine's scores are **bit-identical** across
    cache off / device cache / host cache, and bit-identical to the jnp
    oracle (one jitted ``dlrm_forward`` per recorded wave shape).  Wave
    formation, bucket set, device-cache counters, and the cache event log
    are pinned as goldens — any behavioural drift in batching, admission,
    or eviction shows up here before it shows up in production."""
    plan = _mixed_plan()
    cfg = DLRMConfig(table_sizes=SIZES, emb_dim=DIM, bottom_mlp=(32, 16),
                     top_mlp=(32,), embedding=plan)
    qp = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    reqs = _trace()

    def run(cache):
        eng = _RecordingEngine(cfg, qp, max_batch=32, cache=cache)
        uids = [eng.submit(d, b) for d, b in reqs]
        done = eng.run_until_drained()
        return np.array([done[u].score for u in uids], np.float32), eng

    dev_cache = DeviceHotRowCache(capacity_rows=4096, record_events=True)
    s_off, eng_off = run(None)
    s_dev, eng_dev = run(dev_cache)
    s_host, _ = run(HotRowCache(capacity_rows=4096))
    np.testing.assert_array_equal(s_dev, s_off)
    # host cache pools/projects in numpy (compat path): its projection
    # matmul may differ from XLA's by 1 ulp on mixed-dim plans
    np.testing.assert_allclose(s_host, s_off, rtol=1e-6, atol=1e-6)

    # oracle: one jitted full forward per recorded wave shape
    oracle = jax.jit(lambda p, d, i, m: dlrm_forward(p, d, i, cfg, mask=m))
    want = {}
    for (dense, idx, mask), uids in eng_dev.trace:
        logits = np.asarray(oracle(qp, jnp.asarray(dense), jnp.asarray(idx),
                                   jnp.asarray(mask)), np.float32)
        for b, uid in enumerate(uids):
            want[uid] = logits[b]
    np.testing.assert_array_equal(
        s_dev, np.array([want[u] for u in range(len(reqs))], np.float32))

    # pinned behavioural goldens
    m = eng_dev.metrics()
    assert m["waves"] == GOLDEN_WAVES, m["waves"]
    assert m["buckets"] == GOLDEN_BUCKETS, m["buckets"]
    assert eng_off.metrics()["waves"] == GOLDEN_WAVES
    assert m["cache"] == GOLDEN_CACHE, m["cache"]
    sha = hashlib.sha1(repr(dev_cache.events).encode()).hexdigest()
    assert sha == GOLDEN_EVENTS_SHA1, sha


def test_tiny_cache_falls_back_bit_identical():
    """A cache smaller than one wave's working set rejects admission and
    serves in-graph — still bit-identical, only slower."""
    cfg = DLRMConfig(table_sizes=SIZES[:3], emb_dim=DIM, bottom_mlp=(32, 16),
                     top_mlp=(32,),
                     embedding=EmbeddingSpec(kind="qr", num_collisions=4,
                                             threshold=40))
    qp = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    reqs = _trace(48, seed=5)
    reqs = [(d, b[:3]) for d, b in reqs]

    def run(cache):
        eng = RecsysEngine(cfg, qp, max_batch=8, cache=cache)
        uids = [eng.submit(d, b) for d, b in reqs]
        done = eng.run_until_drained()
        return np.array([done[u].score for u in uids], np.float32)

    s_off = run(None)
    tiny = DeviceHotRowCache(capacity_rows=2)
    np.testing.assert_array_equal(run(tiny), s_off)
    assert tiny.stats.rejections > 0


def test_continuous_batching_groups_by_bucket_and_serves_head_first():
    """Wave formation: same-bucket requests coalesce (no pow2 cross-bucket
    padding), and the queue head always anchors the next wave — a long-bag
    head cannot be starved by a run of short requests behind it."""
    cfg = DLRMConfig(table_sizes=SIZES[:2], emb_dim=DIM, bottom_mlp=(16,),
                     top_mlp=(16,),
                     embedding=EmbeddingSpec(kind="qr", num_collisions=4,
                                             threshold=1000))
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    # max_inflight=0: reap synchronously so each step returns its own wave
    eng = RecsysEngine(cfg, params, max_batch=4, max_inflight=0)
    long_uid = eng.submit(np.zeros(13), [[1] * 9, [2] * 9])      # bucket 16
    for k in range(6):
        eng.submit(np.zeros(13), [[k], [k]])                     # bucket 1
    first = eng.step()
    assert [r.uid for r in first] == [long_uid]    # head anchors, ships alone
    eng.run_until_drained()
    assert set(eng.metrics()["buckets"]) == {(1, 16), (4, 1), (2, 1)}

    # legacy mode: strict FIFO slices (one mixed wave padded to (4, 16))
    eng_w = RecsysEngine(cfg, params, max_batch=4, batching="waves")
    eng_w.submit(np.zeros(13), [[1] * 9, [2] * 9])
    for k in range(3):
        eng_w.submit(np.zeros(13), [[k], [k]])
    eng_w.run_until_drained()
    assert eng_w.metrics()["buckets"] == [(4, 16)]


def test_engine_rejects_unknown_batching_mode():
    cfg = DLRMConfig(table_sizes=SIZES[:2], emb_dim=DIM,
                     embedding=EmbeddingSpec(kind="qr", num_collisions=4))
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="batching"):
        RecsysEngine(cfg, params, batching="nope")
